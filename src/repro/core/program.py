"""StepProgram IR: declarative per-bucket execution plans for the optimizer
hot path, and the single lowering path that runs them.

Motivation (PR 5): PRs 1-4 grew three hand-built execution regimes —
replicated, column-sharded and row-sharded — whose dispatch logic was
smeared across ``subtrack.update`` (shard_info_for / axis-name plumbing),
``subspace`` (track_subspace vs track_subspace_rowsharded),
``lowrank_adam`` (per-regime psum placement) and ``distributed/sharding``.
This module makes the per-leaf execution scheme a first-class object:

* :func:`build_program` classifies a :class:`~repro.core.plan.ParamPlan`
  (+ config + mesh) into a :class:`StepProgram` — the regime, the
  shard_map axes, the Adam-state layout, the tracking schedule, and the
  full list of :class:`CollectiveRound`\\ s (name, kind, payload shape)
  the step is allowed to execute;
* :func:`regime_rounds` is the **single source of truth** for the
  collective structure: the byte model in :mod:`repro.kernels.traffic`
  charges wire bytes off these rounds, the HLO pins in
  ``tests/test_mesh_fused.py`` assert compiled collective counts against
  :meth:`StepProgram.collective_counts`, and the runtime
  :class:`Exec`\\ utor will only fire collectives the program declares —
  three consumers, one definition, no drift possible;
* :func:`lower` turns a per-matrix step function into the shard_map'd
  (or plain) runner, deriving every in/out PartitionSpec from the
  program's declared layouts;
* :class:`Exec` is the runtime face of a program inside the lowered
  step: the math code in ``subspace`` / ``lowrank_adam`` expresses its
  schedule once, invoking collectives **by round name**
  (``exec.collective("proj", x)``); rounds the program does not declare
  are identities, so one code path serves all four regimes.

The five regimes
----------------
========== ============ =============== ======================================
regime     G/S layout   M/V layout      collectives (plain / tracking)
========== ============ =============== ======================================
replicated whole leaf   whole leaf      none (single device / GSPMD)
column     n sharded    n sharded       clip scalar AR / + (m, r) tangent AR
row        m sharded    replicated      (r+1, n) proj AR / + (r, n+3r) Gram AR
row-rs     m sharded    n/g slice       (r+1, n) proj RS + epilogue AG /
                                        proj AR + Gram AR + epilogue AG
grass      whole leaf   whole leaf      local ``sel_gather`` round only: S is
                                        a one-hot row selection (Grass,
                                        arXiv:2406.17660), A = S^T G a gather
========== ============ =============== ======================================

Grad-fused plain steps (PR 6) additionally declare a local ``grad_tap``
round in the replicated / column / grass regimes: the (r+1, n)
[A; colnorms] panel is produced by the model's backward-pass epilogue
(``kernels.ops.grad_tap`` via ``models.common.tapped_matmul``) and the
optimizer consumes it instead of re-reading the full-width gradient.
Local rounds are zero-wire and compile to no HLO collective — the pins
and the ring model see straight through them.

``row-rs`` is the reduce-scatter flavour of the row regime (the ROADMAP
item this PR lands): instead of psumming the stacked (r+1, n)
[A; colnorms] panel to every row shard and recomputing the full-width
Adam pass redundantly (replicated M/V — the row regime's memory cost),
the panel is reduce-SCATTERED so each shard owns an n/g column slice of
M/V, the Adam pass runs sharded, and one all-gather of the
[G~; G~^O; phi; clip-partials] panel restores full width right before
``fused_update`` writes the local rows.  Per-device M/V memory drops by
the group factor and the sliced state passes outweigh the extra gather
wire everywhere inside the row gate (see the byte comparison in
``_row_flavor`` and ``traffic.sharded_row_rs_*``).  Tracking steps keep
the row regime's all-reduce front end (the tangent needs global A) and
shard only the rotation + Adam passes, gathering [G~^O; phi; partials]
at the end — exactly 2 collectives plain / 3 tracking.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional

import numpy as np

from repro.core import plan as plan_lib

F32 = 4

REGIMES = ("replicated", "column", "row", "row-rs", "grass")

# collective kinds (HLO opcode names — hlo_analysis counts these)
ALL_REDUCE = "all-reduce"
REDUCE_SCATTER = "reduce-scatter"
ALL_GATHER = "all-gather"
# Local (non-collective) round kind: a declared data-flow edge of the
# step — the backward-pass tap panel a grad-fused step consumes, or the
# Grass row gather — with zero wire bytes and no HLO collective op.  It
# exists in the IR so the traffic model, the executor gates
# (``Exec.has``) and the tools see one declaration, same as the real
# collectives.
GRAD_FUSED = "grad-fused"


@dataclass(frozen=True)
class CollectiveRound:
    """One declared collective of a step program.

    ``rows, cols`` are the logical 2-D payload shape: the pre-collective
    per-device operand for all-reduce / reduce-scatter, the *gathered*
    (output) panel for all-gather — in both conventions this is the HLO
    result-bytes quantity the ring wire model multiplies.
    """

    name: str          # semantic label the runtime invokes it by
    kind: str          # ALL_REDUCE | REDUCE_SCATTER | ALL_GATHER
    rows: int
    cols: int
    dtype_bytes: int = F32

    @property
    def payload_bytes(self) -> int:
        return self.rows * self.cols * self.dtype_bytes

    def wire_bytes(self, group: int) -> int:
        """Per-device ring-model wire bytes (matching
        repro.distributed.hlo_analysis: AR = 2(g-1)/g * result, RS =
        (g-1)/g * result * g with result = payload/g, AG = (g-1)/g *
        gathered result)."""
        if self.kind == GRAD_FUSED or group <= 1:
            return 0
        ring = (group - 1) / group
        if self.kind == ALL_REDUCE:
            return int(2.0 * ring * self.payload_bytes)
        if self.kind in (REDUCE_SCATTER, ALL_GATHER):
            return int(ring * self.payload_bytes)
        raise ValueError(f"unknown collective kind {self.kind!r}")


def regime_rounds(regime: str, m: int, n: int, r: int, group: int, *,
                  tracking: bool, recovery: bool = True,
                  tapped: bool = False
                  ) -> tuple[CollectiveRound, ...]:
    """The collective rounds of one optimizer step — the single source of
    truth consumed by the runtime executor, the traffic byte model and
    the HLO count pins.

    Round names are the contract with the lowered code paths:

    * ``proj``            — makes the stacked (r+1, n) [A; colnorms]
                            projection panel global (row regimes; the
                            projection contracts over sharded rows);
    * ``tangent_psum``    — (m, r) tangent accumulator psum (column
                            tracking; T is linear in W = G A^T);
    * ``gram_psum``       — fused (r, n + 3r) [T^T G | S^T T | T^T T |
                            S^T S] psum (row-family tracking; the Gram
                            is quadratic in ``proj``'s result, so this
                            second round is provably irreducible);
    * ``clip``            — the Eq. 12 scalar psum (column; the row
                            family gets the clip free off replicated or
                            gathered per-column quantities);
    * ``epilogue_gather`` — row-rs only: all-gather of the stacked
                            per-column epilogue panel ([G~; ] G~^O; phi;
                            clip partials) back to full width before
                            ``fused_update``;
    * ``grad_tap``        — grad-fused plain steps (``tapped=True``):
                            the (r+1, n) [A; colnorms] panel emitted by
                            the backward-pass epilogue that replaces the
                            optimizer's own projection read of G.  Local
                            kind, zero wire bytes;
    * ``sel_gather``      — Grass regime: S is a one-hot row selection,
                            so A = S^T G is an (r, n) row gather of G
                            (no MXU projection).  Local kind.
    """
    tap = ((CollectiveRound("grad_tap", GRAD_FUSED, r + 1, n),)
           if tapped and not tracking else ())
    if regime == "grass":
        # the tap subsumes the gather (it IS the gathered rows + norms)
        return tap if tap else (
            CollectiveRound("sel_gather", GRAD_FUSED, r, n),)
    if group <= 1 or regime == "replicated":
        return tap
    if regime == "column":
        rounds = list(tap)
        if tracking:
            rounds.append(CollectiveRound("tangent_psum", ALL_REDUCE, m, r))
        if recovery:
            rounds.append(CollectiveRound("clip", ALL_REDUCE, 1, 1))
        return tuple(rounds)
    if regime == "row":
        rounds = [CollectiveRound("proj", ALL_REDUCE, r + 1, n)]
        if tracking:
            rounds.append(CollectiveRound("gram_psum", ALL_REDUCE,
                                          r, n + 3 * r))
        return tuple(rounds)
    if regime == "row-rs":
        if tracking:
            # AR front end (the tangent needs global A), sharded
            # rotation+Adam, then gather [G~^O; phi; partials] — G~ (the
            # new-basis projection) is already global via the rank-1
            # identity, so it is NOT re-gathered
            gathered = (r + 2) if recovery else r
            return (CollectiveRound("proj", ALL_REDUCE, r + 1, n),
                    CollectiveRound("gram_psum", ALL_REDUCE, r, n + 3 * r),
                    CollectiveRound("epilogue_gather", ALL_GATHER,
                                    gathered, n))
        # plain: scatter the projection so the Adam pass runs on the
        # (r, n/g) slice; the gather restores [G~; G~^O; phi; partials]
        gathered = (2 * r + 2) if recovery else r
        return (CollectiveRound("proj", REDUCE_SCATTER, r + 1, n),
                CollectiveRound("epilogue_gather", ALL_GATHER, gathered, n))
    raise ValueError(f"unknown regime {regime!r}")


@dataclass(frozen=True)
class StepProgram:
    """Declarative description of one bucket's optimizer step.

    Static and hashable (like ParamPlan); built at trace time, never
    enters the jitted graph.  ``axes`` empty means the plain (GSPMD /
    single-device) path: no shard_map, every round an identity.
    """

    regime: str                 # one of REGIMES
    axes: tuple                 # shard_map mesh axes; () = plain path
    shards: int                 # total group size over `axes`
    m: int
    n: int
    rank: int
    tracking: bool              # which step kind this program describes
    tracks: bool                # effective geometry: does the refresh
    #                             actually move the basis?  False for
    #                             plain steps AND for tracking steps of
    #                             frozen-subspace methods — such steps
    #                             declare (and the byte model charges)
    #                             the plain rounds
    recovery: bool
    rounds: tuple               # tuple[CollectiveRound, ...]
    grad_layout: str            # "replicated" | "column" | "row"
    state_layout: str           # M/V: "inherit" | "column" | "replicated"
    #                             | "slice" (n/g column slice per row shard)
    schedule: str               # tracking geometry: "tangent" | "gram"

    def round(self, name: str) -> Optional[CollectiveRound]:
        for rnd in self.rounds:
            if rnd.name == name:
                return rnd
        return None

    def collective_counts(self) -> dict[str, int]:
        """{HLO opcode: count} — what tests pin compiled programs
        against (see tests/test_mesh_fused.py / tests/test_program.py).
        Local rounds (kind ``grad-fused``) lower to no collective op, so
        they are excluded: a grad-fused program compiles to the same HLO
        collective counts as its untapped sibling."""
        counts: dict[str, int] = {}
        for rnd in self.rounds:
            if rnd.kind not in (ALL_REDUCE, REDUCE_SCATTER, ALL_GATHER):
                continue
            counts[rnd.kind] = counts.get(rnd.kind, 0) + 1
        return counts

    def collective_wire_bytes(self) -> int:
        """Per-device ring-model wire bytes of all rounds — the term the
        traffic byte model charges on top of local HBM bytes."""
        return sum(rnd.wire_bytes(self.shards) for rnd in self.rounds)

    def describe(self) -> str:
        """Human-readable program listing (tools/dump_program.py)."""
        lines = [f"StepProgram[{self.regime}] "
                 f"({'tracking' if self.tracking else 'plain'} step, "
                 f"m={self.m} n={self.n} r={self.rank} "
                 f"shards={self.shards} axes={self.axes or '-'})",
                 f"  grad layout : {self.grad_layout}",
                 f"  M/V layout  : {self.state_layout}",
                 f"  schedule    : {self.schedule}"]
        if not self.rounds:
            lines.append("  collectives : none")
        for rnd in self.rounds:
            lines.append(
                f"  collective  : {rnd.name:16s} {rnd.kind:15s} "
                f"payload ({rnd.rows}, {rnd.cols}) "
                f"= {rnd.payload_bytes} B, "
                f"wire {rnd.wire_bytes(self.shards)} B/device")
        return "\n".join(lines)


_GRAD_LAYOUT = {"replicated": "replicated", "column": "column",
                "row": "row", "row-rs": "row", "grass": "replicated"}
_STATE_LAYOUT = {"replicated": "inherit", "column": "column",
                 "row": "replicated", "row-rs": "slice", "grass": "inherit"}
_SCHEDULE = {"replicated": "tangent", "column": "tangent",
             "row": "gram", "row-rs": "gram", "grass": "tangent"}


def pick_row_flavor(m: int, n: int, r: int, group: int,
                    row_state: str = "auto") -> str:
    """THE row-family state-flavour policy: replicated M/V ("row") or
    the reduce-scatter slice layout ("row-rs").

    ``row_state`` forces a flavour ("replicated" / "reduce-scatter");
    "auto" compares the modeled per-device plain-step bytes (the
    k-1-of-k hot path) and takes the cheaper one.  row-rs additionally
    needs n divisible by the group (the scatter slices columns evenly) —
    a forced "reduce-scatter" degrades to "row" when it isn't.  Single
    implementation shared by :func:`build_program` and the layout
    builder (``distributed/sharding._row_bytes``), so the ranking and
    the executed flavour cannot drift.
    """
    if row_state == "replicated" or n % group != 0:
        return "row"
    if row_state == "reduce-scatter":
        return "row-rs"
    from repro.kernels import traffic  # lazy: traffic reads our rounds

    rs = traffic.sharded_row_rs_fused_step_bytes(m, n, r, group).total
    rep = traffic.sharded_row_fused_step_bytes(m, n, r, group).total
    return "row-rs" if rs < rep else "row"


def _row_flavor(cfg, m: int, n: int, r: int, group: int) -> str:
    return pick_row_flavor(m, n, r, group,
                           getattr(cfg, "row_state", "auto"))


def build_program(plan: plan_lib.ParamPlan, cfg, mesh, *,
                  tracking: bool, tapped: bool = False) -> StepProgram:
    """Classify one leaf (or bucket representative) into its StepProgram.

    This is the regime dispatch that used to live in
    ``subtrack.update.shard_info_for`` + ``plan.spec_regime``: a leaf
    enters a shard_map'd regime only when the optimizer was built with a
    mesh + specs, runs the fused kernels, and — on tracking steps — uses
    a shardable refresh method ("grassmann" / "none"; the SVD/random/Oja
    refreshes contract over all columns).  Row-family regimes route
    reorth-scrubbing tracking steps away (a QR of the row-sharded basis
    is not shard-local).  Everything else lowers to the replicated
    program: no shard_map, plain GSPMD propagation, zero declared
    rounds.

    ``tapped`` marks a plain step whose (r+1, n) [A; colnorms] panel
    arrives precomputed from the backward pass (the grad-fused path).
    Only the regimes whose projection the model-side tap can legally
    replace accept it — replicated, column (the tap is column-separable,
    see ``kernels.ops.grad_tap``) and grass; the row family contracts A
    over sharded rows the tap never sees, so ``tapped`` is ignored there
    and the caller falls back to the untapped program.
    """
    m, n, r = plan.m, plan.n, plan.rank
    method = getattr(cfg, "method", "grassmann")
    regime, axes = "replicated", ()
    if plan.mode == "lowrank" and method == "grass":
        # Grass never shard_maps: the top-r row selection contracts over
        # all columns (like the SVD refresh), so the leaf stays on plain
        # GSPMD propagation with the gather declared as a local round.
        regime = "grass"
    elif (mesh is not None and getattr(cfg, "use_kernels", False)
            and plan.mode == "lowrank"
            and not (tracking and cfg.method not in ("grassmann", "none"))):
        col = plan_lib.spec_column_axes(plan)
        row = plan_lib.spec_row_axes(plan)
        if col is not None:
            regime, axes = "column", col
        elif row is not None and not (tracking and cfg.method == "grassmann"
                                      and cfg.reorth_interval):
            regime, axes = "row", row
    shards = (int(np.prod([mesh.shape[a] for a in axes])) if axes else 1)
    if regime == "row":
        regime = _row_flavor(cfg, m, n, r, shards)
    recovery = bool(getattr(cfg, "recovery", True))
    tapped = tapped and not tracking and regime in ("replicated", "column",
                                                   "grass")
    # Rounds reflect the EFFECTIVE geometry: a tracking step whose
    # refresh method moves no basis (method="none" — the frozen-subspace
    # ablation) fires no geodesic collectives, so it declares (and the
    # byte model charges, and the HLO pins expect) the plain rounds.
    tracks = tracking and method in ("grassmann", "grass")
    return StepProgram(
        regime=regime, axes=tuple(axes), shards=shards, m=m, n=n, rank=r,
        tracking=tracking, tracks=tracks, recovery=recovery,
        rounds=regime_rounds(regime, m, n, r, shards, tracking=tracks,
                             recovery=recovery, tapped=tapped),
        grad_layout=_GRAD_LAYOUT[regime],
        state_layout=_STATE_LAYOUT[regime],
        schedule=_SCHEDULE[regime])


# ---------------------------------------------------------------------------
# Checkpoint-facing descriptors: the serializable face of a StepProgram
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class StateDescriptor:
    """Serializable per-leaf record of HOW one optimizer-state leaf was
    (or will be) laid out: the StepProgram fields a checkpoint must carry
    so a restore under a *different* program can transpose the state
    (``repro.checkpoint.transpose``).

    ``kind`` is "lowrank" (a MatrixOptState leaf) or "dense" (plain Adam
    state).  For low-rank leaves, ``m, n, rank`` are the canonical
    (post-transpose) dims, ``method`` the refresh family ("grassmann"-like
    dense bases vs "grass" one-hot row selections — the two need a basis
    conversion, everything else is layout-only), and the layout fields
    mirror :class:`StepProgram`.  Not a pytree node: a descriptor is a
    LEAF of the descriptor pytree ``state_leaf_descriptors`` returns.
    """

    kind: str                     # "lowrank" | "dense"
    regime: str = "replicated"
    axes: tuple = ()
    shards: int = 1
    grad_layout: str = "replicated"
    state_layout: str = "inherit"
    schedule: str = "tangent"
    m: int = 0
    n: int = 0
    rank: int = 0
    batch_dims: int = 0
    method: str = "grassmann"

    def to_dict(self) -> dict:
        return {
            "kind": self.kind, "regime": self.regime,
            "axes": [str(a) for a in self.axes], "shards": int(self.shards),
            "grad_layout": self.grad_layout,
            "state_layout": self.state_layout, "schedule": self.schedule,
            "m": int(self.m), "n": int(self.n), "rank": int(self.rank),
            "batch_dims": int(self.batch_dims), "method": self.method,
        }

    @classmethod
    def from_dict(cls, d: dict) -> "StateDescriptor":
        return cls(kind=d["kind"], regime=d.get("regime", "replicated"),
                   axes=tuple(d.get("axes", ())),
                   shards=int(d.get("shards", 1)),
                   grad_layout=d.get("grad_layout", "replicated"),
                   state_layout=d.get("state_layout", "inherit"),
                   schedule=d.get("schedule", "tangent"),
                   m=int(d.get("m", 0)), n=int(d.get("n", 0)),
                   rank=int(d.get("rank", 0)),
                   batch_dims=int(d.get("batch_dims", 0)),
                   method=d.get("method", "grassmann"))


def descriptor_for(plan: plan_lib.ParamPlan, cfg, mesh) -> StateDescriptor:
    """One leaf's StateDescriptor — built off the same ``build_program``
    classification the plain-step hot path runs under, so the recorded
    layout IS the executed one."""
    if plan.mode != "lowrank":
        return StateDescriptor(kind="dense")
    prog = build_program(plan, cfg, mesh, tracking=False)
    return StateDescriptor(
        kind="lowrank", regime=prog.regime, axes=prog.axes,
        shards=prog.shards, grad_layout=prog.grad_layout,
        state_layout=prog.state_layout, schedule=prog.schedule,
        m=prog.m, n=prog.n, rank=prog.rank, batch_dims=plan.batch_dims,
        method=getattr(cfg, "method", "grassmann"))


def state_leaf_descriptors(params, cfg, mesh=None, param_specs=None):
    """Pytree mirroring ``params`` of per-leaf :class:`StateDescriptor`.

    This is the accessor the checkpoint layer consumes: on save the
    descriptors are embedded in the manifest's ``extra_meta`` (source
    programs); on restore they are rebuilt for the *current* mesh/config
    and become the transpose targets.  ``cfg`` is any optimizer config —
    one without a ``rank`` (the dense baselines) yields all-dense
    descriptors, so every optimizer checkpoints through the same path.
    """
    import jax

    rank = getattr(cfg, "rank", 0)
    if not rank:
        return jax.tree.map(lambda _: StateDescriptor(kind="dense"), params)
    plans = plan_lib.make_plans(params, rank, specs=param_specs)
    return jax.tree.map(
        lambda plan: descriptor_for(plan, cfg, mesh), plans,
        is_leaf=lambda x: isinstance(x, plan_lib.ParamPlan))


# ---------------------------------------------------------------------------
# Runtime execution: named-round collectives inside the lowered step
# ---------------------------------------------------------------------------


class Exec:
    """Runtime face of a StepProgram inside the lowered per-matrix step.

    The math in ``subspace`` / ``lowrank_adam`` is written once against
    this interface: collectives are invoked by round name and are
    identities unless the program declares them, layout questions
    (``state_slice``, ``state_width``) answer from the program's
    declared layouts.  One instance is built per bucket per step kind
    (:func:`executor`); the replicated singleton :data:`NULL_EXEC` serves
    every exec-less caller (tests, benchmarks, the legacy jnp path).
    """

    def __init__(self, program: StepProgram):
        self.program = program
        axes = program.axes
        self.axis = None if not axes else (axes if len(axes) > 1
                                           else axes[0])

    # --- program data reads -------------------------------------------
    @property
    def schedule(self) -> str:
        return self.program.schedule

    @property
    def rows_sharded(self) -> bool:
        return self.program.grad_layout == "row"

    def has(self, name: str) -> bool:
        return self.program.round(name) is not None

    def state_width(self, n: int) -> int:
        """Columns of the Adam-state block this shard owns."""
        if self.program.state_layout == "slice":
            return n // self.program.shards
        return n

    # --- communication primitives -------------------------------------
    def _axis_index(self):
        import jax

        axes = self.program.axes
        idx = jax.lax.axis_index(axes[0])
        for a in axes[1:]:
            idx = idx * jax.lax.psum(1, a) + jax.lax.axis_index(a)
        return idx

    def collective(self, name: str, x):
        """Execute round ``name`` on ``x`` — identity when the program
        does not declare it (or the program is unsharded)."""
        rnd = self.program.round(name)
        if rnd is None or rnd.kind == GRAD_FUSED or self.axis is None:
            return x
        import jax

        if rnd.kind == ALL_REDUCE:
            return jax.lax.psum(x, self.axis)
        if rnd.kind == REDUCE_SCATTER:
            return jax.lax.psum_scatter(x, self.axis,
                                        scatter_dimension=x.ndim - 1,
                                        tiled=True)
        if rnd.kind == ALL_GATHER:
            return jax.lax.all_gather(x, self.axis, axis=x.ndim - 1,
                                      tiled=True)
        raise ValueError(f"unknown collective kind {rnd.kind!r}")

    def psum(self, x):
        """Raw psum over the program axes (legacy unfused-path reductions
        that predate the fused rounds); identity when unsharded."""
        if self.axis is None:
            return x
        import jax

        return jax.lax.psum(x, self.axis)

    def state_slice(self, x):
        """This shard's Adam-state column block of a replicated-width
        array (identity unless the program's state layout is "slice")."""
        if self.program.state_layout != "slice" or self.axis is None:
            return x
        import jax

        n_loc = x.shape[-1] // self.program.shards
        return jax.lax.dynamic_slice_in_dim(
            x, self._axis_index() * n_loc, n_loc, axis=x.ndim - 1)


NULL_PROGRAM = StepProgram(
    regime="replicated", axes=(), shards=1, m=0, n=0, rank=0,
    tracking=False, tracks=False, recovery=True, rounds=(),
    grad_layout="replicated", state_layout="inherit", schedule="tangent")

NULL_EXEC = Exec(NULL_PROGRAM)


def executor(program: StepProgram) -> Exec:
    # Unsharded programs usually share the null executor, but a program
    # that declares rounds even at group 1 (grass gather, grad-fused
    # taps) needs its own Exec so ``has()`` answers from ITS rounds.
    if not program.axes and not program.rounds:
        return NULL_EXEC
    return Exec(program)


# ---------------------------------------------------------------------------
# Lowering: program -> (shard_map'd or plain) stacked runner
# ---------------------------------------------------------------------------


def lower(program: StepProgram, fn: Callable, *, mesh, batch_dims: int,
          with_param: bool, with_tap: bool = False,
          with_health: bool = False) -> Callable:
    """Turn the per-bucket stacked step ``fn(g, st[, p][, tap]) ->
    (delta, st'[, diag])`` into the program's runner.

    Replicated programs return ``fn`` unchanged (plain jit path, GSPMD
    propagation).  Sharded programs wrap ``fn`` in ``shard_map`` with
    every in/out PartitionSpec derived from the program's declared
    layouts: the gradient/param/update panels follow ``grad_layout``, S
    shards with the gradient rows, M/V follow ``state_layout`` ("column"
    and "slice" both shard the global (r, n) state arrays along n —
    the slice layout simply pairs that with a row-sharded gradient),
    and ``lam_prev`` replicates.  ``with_tap`` appends the grad-fused
    (r+1, n) [A; colnorms] panel as the trailing argument; it shards
    along n with the gradient columns (the tap is column-separable), so
    inside the column regime each shard consumes exactly its slice.
    ``with_health`` appends a third output: the per-matrix
    (health.DIAG_SIZE,) diagnostic vector, replicated — sigma/theta and
    the guard flags derive from psum'd quantities, so every shard holds
    the same values under both tracking schedules.
    """
    if not program.axes:
        return fn
    import jax
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.core.lowrank_adam import MatrixOptState

    ax = program.axes if len(program.axes) > 1 else program.axes[0]
    lead = (None,) * batch_dims
    if program.grad_layout == "column":
        gspec = P(*lead, None, ax)
        s_spec = P(*lead, None, None)
    else:                                        # row family
        gspec = P(*lead, ax, None)
        s_spec = P(*lead, ax, None)
    mv = {"column": P(*lead, None, ax),
          "replicated": P(*lead, None, None),
          "slice": P(*lead, None, ax)}[program.state_layout]
    stspec = MatrixOptState(S=s_spec, M=mv, V=mv, lam_prev=P(*lead))
    in_specs = (gspec, stspec) + ((gspec,) if with_param else ())
    if with_tap:
        in_specs = in_specs + (P(*lead, None, ax),)
    out_specs = (gspec, stspec)
    if with_health:
        out_specs = out_specs + (P(*lead, None),)
    sharded = shard_map(fn, mesh=mesh, in_specs=in_specs,
                        out_specs=out_specs, check_rep=False)
    return sharded
