"""Config registry: ``--arch <id>`` resolution for every launcher.

Includes the 10 assigned architectures and the paper's own Llama
pre-training ladder (Table 10) used by the reproduction benchmarks.
"""

from __future__ import annotations

import importlib

from repro.models.config import ModelConfig, reduce_for_smoke

# assigned architecture id -> module (exact configs from the assignment)
_ARCH_MODULES = {
    "minicpm3-4b": "repro.configs.minicpm3_4b",
    "stablelm-12b": "repro.configs.stablelm_12b",
    "gemma2-27b": "repro.configs.gemma2_27b",
    "qwen1.5-4b": "repro.configs.qwen1_5_4b",
    "mixtral-8x22b": "repro.configs.mixtral_8x22b",
    "llama4-maverick-400b-a17b": "repro.configs.llama4_maverick",
    "qwen2-vl-2b": "repro.configs.qwen2_vl_2b",
    "zamba2-7b": "repro.configs.zamba2_7b",
    "xlstm-125m": "repro.configs.xlstm_125m",
    "seamless-m4t-large-v2": "repro.configs.seamless_m4t_large_v2",
}


def _llama(name, layers, d, heads, ff) -> ModelConfig:
    """Paper Table 10 Llama-based pre-training architectures."""
    return ModelConfig(
        name=name, family="decoder", n_layers=layers, d_model=d,
        n_heads=heads, n_kv_heads=heads, d_ff=ff, vocab_size=32000,
        rope_theta=10000.0, vocab_round=64)


# paper's pre-training ladder (hidden/intermediate/heads/layers, Table 10)
_PAPER_MODELS = {
    "llama-60m": _llama("llama-60m", 8, 512, 8, 1376),
    "llama-130m": _llama("llama-130m", 12, 768, 12, 2048),
    "llama-350m": _llama("llama-350m", 24, 1024, 16, 2736),
    "llama-1b": _llama("llama-1b", 32, 2048, 24, 5461),
    "llama-3b": _llama("llama-3b", 32, 2560, 32, 6848),
    "llama-7b": _llama("llama-7b", 32, 4096, 32, 11008),
    # ~100M model for the end-to-end example driver
    "llama-100m": _llama("llama-100m", 12, 640, 10, 1708),
}

# paper Table 10 low-rank ranks per model size
PAPER_RANKS = {
    "llama-60m": 128, "llama-130m": 256, "llama-350m": 256,
    "llama-1b": 512, "llama-3b": 512, "llama-7b": 1024,
    "llama-100m": 128,
}

ASSIGNED_ARCHS = tuple(_ARCH_MODULES)


def arch_names() -> list[str]:
    return sorted(list(_ARCH_MODULES) + list(_PAPER_MODELS))


def get_config(name: str, smoke: bool = False) -> ModelConfig:
    """Resolve an architecture id; ``smoke=True`` returns the reduced
    same-family config used by CPU smoke tests."""
    if name in _ARCH_MODULES:
        cfg = importlib.import_module(_ARCH_MODULES[name]).CONFIG
    elif name in _PAPER_MODELS:
        cfg = _PAPER_MODELS[name]
    else:
        raise ValueError(f"unknown arch {name!r}; options: {arch_names()}")
    return reduce_for_smoke(cfg) if smoke else cfg
