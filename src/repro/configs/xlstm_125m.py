"""xlstm-125m [ssm] — sLSTM + mLSTM blocks.
12L d_model=768 4H (GQA kv=4) d_ff=0 vocab=50304
[arXiv:2405.04517; unverified]

xLSTM[3:1] layout: every 4th block is an sLSTM (positions 3, 7, 11), the
rest are mLSTMs.  d_ff=0 per the assignment — blocks carry their own
projections (mLSTM pre-up x2, sLSTM post-FFN x4/3).
"""

from repro.models.config import ModelConfig, XLSTMConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="xlstm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    xlstm=XLSTMConfig(slstm_every=4, mlstm_proj_factor=2.0,
                      slstm_proj_factor=4.0 / 3.0, conv_kernel=4, chunk=64),
)
