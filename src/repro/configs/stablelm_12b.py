"""stablelm-12b [dense].
40L d_model=5120 32H (GQA kv=8) d_ff=13824 vocab=100352
[hf:stabilityai/stablelm-2-12b; hf]  Partial rotary (25%) per the
StableLM-2 family config.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-12b",
    family="decoder",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    d_ff=13824,
    vocab_size=100352,
    rope_pct=0.25,
    rope_theta=10000.0,
)
