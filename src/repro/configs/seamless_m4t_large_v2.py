"""seamless-m4t-large-v2 [audio] — encoder-decoder, multimodal.
24L d_model=1024 16H (GQA kv=16) d_ff=8192 vocab=256206
[arXiv:2308.11596; hf]

24 encoder + 24 decoder layers (the assignment's 24L applies to each
stack).  The speech frontend is a STUB: input_specs supplies precomputed
frame embeddings (B, S, d).  Decode shapes use a 4096-frame encoder memory
with the decoder-side KV cache at the shape's seq_len.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    n_layers=48,
    n_enc_layers=24,
    n_dec_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab_size=256206,
    audio_frontend=True,
    enc_memory_len=4096,
)
