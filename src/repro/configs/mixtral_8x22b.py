"""mixtral-8x22b [moe] — 8 experts top-2, sliding-window attention.
56L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=32768, MoE 8e top-2
[arXiv:2401.04088; hf]  Window 4096 on every layer => ring-buffered decode
caches and long_500k eligibility.
"""

from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="decoder",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab_size=32768,
    sliding_window=4096,
    moe=MoEConfig(n_experts=8, top_k=2, d_ff=16384, capacity_factor=1.25),
    rope_theta=1000000.0,
)
