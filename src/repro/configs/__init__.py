"""Architecture configs: one module per assigned arch + the paper's own
Llama pre-training sizes.  Access through ``repro.configs.registry``.
"""
