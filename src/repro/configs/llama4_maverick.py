"""llama4-maverick-400b-a17b [moe] — 128-expert top-1 MoE + shared expert.
48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048, MoE 128e top-1
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]

Per the assignment line every layer is MoE (128 routed experts, top-1
sigmoid gate) with one always-on shared expert of the same width — the
Maverick routed/shared split.  Early-fusion multimodality is out of scope
for the text backbone (DESIGN.md §3).
"""

from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="decoder",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    moe=MoEConfig(n_experts=128, top_k=1, d_ff=8192,
                  n_shared_experts=1, shared_d_ff=8192,
                  capacity_factor=1.25),
    rope_theta=500000.0,
)
