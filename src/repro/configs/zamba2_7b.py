"""zamba2-7b [hybrid] — Mamba2 backbone + shared attention blocks.
81L d_model=3584 32H (GQA kv=32) d_ff=14336 vocab=32000, ssm_state=64
[arXiv:2411.15242; unverified]

81 Mamba2 layers with the weight-shared attention+MLP block applied every
9 layers (81 = 9 x 9 uniform groups; the released model interleaves two
shared blocks aperiodically — simplification noted in DESIGN.md).  The
shared block consumes concat(embeddings, hidden) through a 2d->d
projection as in the paper.
"""

from repro.models.config import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="zamba",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,
    vocab_size=32000,
    head_dim=112,
    attn_every=9,
    ssm=SSMConfig(d_state=64, head_dim=64, expand=2, conv_kernel=4,
                  chunk=128),
    rope_theta=10000.0,
)
