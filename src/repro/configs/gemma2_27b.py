"""gemma2-27b [dense] — local/global alternating attention, logit softcaps.
46L d_model=4608 32H (GQA kv=16) d_ff=36864 vocab=256000
[arXiv:2408.00118; hf]  head_dim 128, window 4096 on local (even) layers,
attn softcap 50, final softcap 30, GeGLU, sandwich norms, sqrt(d) embed
scale.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b",
    family="decoder",
    n_layers=46,
    d_model=4608,
    n_heads=32,
    n_kv_heads=16,
    d_ff=36864,
    vocab_size=256000,
    head_dim=128,
    attn_softcap=50.0,
    final_softcap=30.0,
    sliding_window=4096,
    local_global_period=2,
    emb_scale=True,
    tie_embeddings=True,
    rope_theta=10000.0,
)
