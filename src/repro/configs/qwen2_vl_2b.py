"""qwen2-vl-2b [vlm] — M-RoPE, dynamic-resolution vision (frontend STUB).
28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936
[arXiv:2409.12191; hf]

The vision tower is a stub: ``input_specs`` supplies precomputed patch
embeddings (1024 tokens/sample for the training shape, the dynamic-
resolution budget of the 2B release) merged into the prefix positions.
M-RoPE sections (16, 24, 24) over head_dim/2 = 64 frequency slots.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family="decoder",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_ff=8960,
    vocab_size=151936,
    qkv_bias=True,
    mrope=True,
    mrope_sections=(16, 24, 24),
    vision_tokens=1024,
    rope_theta=1000000.0,
)
