"""End-to-end pre-training driver (paper Table 1 / Fig. 4 at local scale):
train the ~100M-parameter Llama config for a few hundred steps with
SubTrack++ and baselines, through the full production loop
(checkpointing, straggler watchdog, warm start, cosine schedule).

    PYTHONPATH=src python examples/pretrain_compare.py \
        [--optimizers subtrack,adamw] [--steps 300] [--scale full|small]

``--scale full`` uses the real llama-100m (12L x 640d, ~100M params) —
a few hundred steps is hours on this 1-core CPU container, so the default
``small`` runs the same driver on the reduced config; EXPERIMENTS.md
records a full-scale run's numbers.
"""

import argparse
import json
from pathlib import Path

from repro.launch.train import train

ap = argparse.ArgumentParser()
ap.add_argument("--optimizers", default="subtrack,galore,adamw")
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--scale", default="small", choices=["small", "full"])
ap.add_argument("--out", default="experiments/pretrain_compare")
args = ap.parse_args()

out_dir = Path(args.out)
out_dir.mkdir(parents=True, exist_ok=True)
results = {}
for name in args.optimizers.split(","):
    base = ["--arch", "llama-100m", "--optimizer", name,
            "--steps", str(args.steps), "--update-interval", "25",
            "--warmup", "20", "--lr", "1e-3",
            "--checkpoint-dir", str(out_dir / f"ckpt_{name}"),
            "--checkpoint-every", "100",
            "--metrics-out", str(out_dir / f"{name}.json")]
    if args.scale == "small":
        base += ["--smoke", "--batch", "8", "--seq", "64", "--rank", "16"]
    else:
        base += ["--batch", "8", "--seq", "256", "--rank", "128"]
    print(f"\n=== {name} ({args.scale}) ===")
    summary = train(base)
    results[name] = {"final_loss": summary["final_loss"],
                     "wall_time_s": summary["wall_time_s"],
                     "state_bytes": summary["state_bytes"]}

print("\n=== comparison ===")
for name, r in sorted(results.items(), key=lambda kv: kv[1]["final_loss"]):
    print(f"{name:12s} loss {r['final_loss']:.4f}  "
          f"wall {r['wall_time_s']:7.1f}s  opt-state {r['state_bytes']/1e6:.1f} MB")
(out_dir / "summary.json").write_text(json.dumps(results, indent=2))
