"""Paper Fig. 5: Grassmannian subspace tracking vs SVD refresh on the
Ackley function — the robustness demo.

    PYTHONPATH=src python examples/ackley_tracking.py
"""

from benchmarks.fig5_ackley import run

for sf in (1.0, 3.0):
    print(f"\n=== scale factor {sf} ===")
    out = run(scale_factor=sf)
    g, s = out["grassmann"], out["svd"]
    print(f"grassmann: final dist {g['final_dist']:.3f}, "
          f"max jump {g['max_jump']:.3f}")
    print(f"svd:       final dist {s['final_dist']:.3f}, "
          f"max jump {s['max_jump']:.3f}")
    if g["max_jump"] < s["max_jump"]:
        print("-> tracking moves smoothly; SVD refresh jumps (paper Fig. 5)")
