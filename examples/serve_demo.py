"""Batched serving demo: prefill + continuous decode on any assigned arch.

    PYTHONPATH=src python examples/serve_demo.py --arch gemma2-27b
(uses the reduced same-family config so it runs on CPU; drop --smoke on
real hardware)
"""

import sys

from repro.launch.serve import serve

args = sys.argv[1:] or ["--arch", "gemma2-27b"]
if "--smoke" not in args:
    args.append("--smoke")
serve(args + ["--requests", "6", "--batch", "3",
              "--prompt-len", "24", "--gen", "12"])
