"""Quickstart: train a small Llama with SubTrack++ in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py

Builds a model from the registry, the SubTrack++ optimizer from the
factory, warm-starts the gradient subspaces (Alg. 1 line 1), and runs a
short training loop with the Alg. 1 `t mod k` tracking cadence.
"""

import jax
import jax.numpy as jnp

from repro.configs.registry import get_config
from repro.core.api import get_optimizer
from repro.data.pipeline import DataConfig, SyntheticLMDataset
from repro.distributed.context import mesh_context
from repro.launch.mesh import smoke_context
from repro.launch.steps import TrainState, make_train_step, make_warm_start
from repro.models.api import build_model

STEPS, K = 40, 10

with mesh_context(smoke_context()):
    cfg = get_config("llama-60m", smoke=True)
    bundle = build_model(cfg)
    optimizer = get_optimizer("subtrack", rank=16, update_interval=K)

    data = SyntheticLMDataset(DataConfig(vocab_size=cfg.vocab_size,
                                         seq_len=64, global_batch=8))
    params = bundle.init(jax.random.PRNGKey(0))
    state = TrainState(params=params, opt=optimizer.init(params))

    train_step = jax.jit(make_train_step(bundle, optimizer),
                         static_argnames=("do_subspace_update",),
                         donate_argnums=(0,))
    state, warm_loss = jax.jit(make_warm_start(bundle, optimizer))(
        state, data.global_batch_at(0))
    print(f"warm-start loss: {float(warm_loss):.4f}")

    for step in range(STEPS):
        state, metrics = train_step(
            state, data.global_batch_at(step), jnp.float32(3e-3),
            do_subspace_update=(step > 0 and step % K == 0))
        if step % 5 == 0 or step == STEPS - 1:
            print(f"step {step:3d}  loss {float(metrics['loss']):.4f}"
                  f"{'   [subspace update]' if step and step % K == 0 else ''}")

    print(f"\noptimizer state: {optimizer.state_bytes(params)/1e3:.0f} KB "
          f"(AdamW would be "
          f"{get_optimizer('adamw').state_bytes(params)/1e3:.0f} KB)")
