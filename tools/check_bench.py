"""Bench-artifact hygiene checker: the committed ``BENCH_kernels.json``
must stay structurally in sync with ``benchmarks/kernels_bench.py``.

The JSON is the machine-readable perf trajectory across PRs; a stale
artifact (sections missing after a bench gains one, agreement loops that
silently regressed, modeled ratios drifting past their documented
targets) would quietly rot.  This checker fails CI fast instead:

* every expected section is present (``hotpath``, ``grad-fused``,
  ``tracking``, ``sharded``, ``sharded-row``, ``sharded-row-rs``) with a
  non-empty ``shapes`` map;
* the numeric agreement loops recorded their worst relative error and it
  is inside the documented budget (1e-5 plain — including the grad-fused
  tap-fed loop — / 1e-3 with tracking steps), plus the sharded-row-rs
  rs-vs-replicated loop;
* modeled traffic ratios respect their targets: hotpath <= 0.5,
  tracking <= 0.7, sharded (column) <= 0.7, sharded-row <= the per-row
  recorded target (0.7 plain / 0.8 tracking near the m/g >= 2r gate
  boundary, 0.7 from m/g >= 4r), sharded-row-rs <= 0.7 both step kinds
  AND below the replicated-M/V flavour's bytes at every cell (the
  StepProgram auto-selection gate), grad-fused <= the per-cell recorded
  target (0.30 with recovery scaling off; the fused ratio itself with it
  on) AND strictly below the fused ratio at every cell (the
  ``below_fused`` booleans — the tap must beat the current fused path
  everywhere or the grad-fused round buys nothing);
* the flat timing ``rows`` list exists and covers every section.

The serving artifact ``BENCH_serve.json`` (from
``benchmarks/serve_bench.py``) is validated too: its three sections
(``load``, ``overload``, ``ttft_bound``) must be present, request
accounting must balance (done + shed + expired == submitted), latency
percentiles must be ordered (p50 <= p99), KV occupancy must be a real
fraction, the overload run must show every degradation mode firing
(shed, expired, OOM-shed, deferrals) while still completing work, and
chunked prefill must bound the worst inter-token gap below the blocking
baseline (``bounded`` true).

Run: ``python tools/check_bench.py [PATH]``.  With no argument BOTH
repo-root artifacts are checked; an explicit path is dispatched on its
name (``*serve*`` -> the serve checker).  Wired into the CI docs job
next to tools/check_docs.py.
"""

from __future__ import annotations

import json
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

EXPECTED_SECTIONS = ("hotpath", "grad-fused", "tracking", "sharded",
                     "sharded-row", "sharded-row-rs")
AGREEMENT_BUDGETS = {"hotpath": 1e-5, "grad-fused": 1e-5, "tracking": 1e-3}
FLAT_RATIO_TARGETS = {"hotpath": 0.5, "tracking": 0.7}
# sections whose per-cell dicts carry their own "target" + an agreement
# loop (or a mesh-skip note) from the fake 8-device mesh
MESH_SECTIONS = ("sharded-row", "sharded-row-rs")


def _iter_ratio_cells(by_shape: dict):
    """Yield (key, dtype_tag, cell) from a sharded-section shapes map
    (cells are {'ratio': ..., 'target': ...?, ...} dicts per dtype)."""
    for kind_key, by_dtype in by_shape.items():
        for tag, cell in by_dtype.items():
            yield kind_key, tag, cell


def check_bench(path: Path) -> list[str]:
    errors: list[str] = []
    try:
        payload = json.loads(path.read_text())
    except FileNotFoundError:
        return [f"{path}: missing — run `PYTHONPATH=src python "
                "benchmarks/kernels_bench.py --json`"]
    except json.JSONDecodeError as e:
        return [f"{path}: not valid JSON ({e})"]

    sections = payload.get("sections", {})
    for name in EXPECTED_SECTIONS:
        if name not in sections:
            errors.append(f"section {name!r} missing — stale artifact?")
            continue
        shapes = sections[name].get("shapes", {})
        if not shapes:
            errors.append(f"section {name!r}: empty 'shapes' map")

    # per-step numeric agreement loops must have run and stayed in budget
    for name, budget in AGREEMENT_BUDGETS.items():
        rel = sections.get(name, {}).get("agreement_rel")
        if rel is None:
            errors.append(f"section {name!r}: no 'agreement_rel' recorded")
        elif rel > budget:
            errors.append(f"section {name!r}: agreement {rel:.2e} "
                          f"exceeds budget {budget}")
    for name in MESH_SECTIONS:
        row = sections.get(name, {})
        agree = row.get("agreement_rel")
        if isinstance(agree, dict):
            if agree.get("plain", 1.0) > 1e-5:
                errors.append(f"{name} plain agreement "
                              f"{agree.get('plain'):.2e} exceeds 1e-5")
            if agree.get("tracking", 1.0) > 1e-3:
                errors.append(f"{name} tracking agreement "
                              f"{agree.get('tracking'):.2e} exceeds 1e-3")
        elif "mesh" not in row:
            errors.append(f"{name}: neither an agreement loop result nor "
                          "a mesh-skip note — regenerate with "
                          "XLA_FLAGS=--xla_force_host_platform_device_"
                          "count=8")

    # modeled ratios against their targets
    for name, target in FLAT_RATIO_TARGETS.items():
        for shape, by_tag in sections.get(name, {}).get("shapes",
                                                        {}).items():
            for tag, ratio in by_tag.items():
                if ratio > target:
                    errors.append(f"{name}/{shape}/{tag}: ratio "
                                  f"{ratio:.3f} > {target}")
    for name in ("sharded", "grad-fused") + MESH_SECTIONS:
        for shape, by_shape in sections.get(name, {}).get("shapes",
                                                          {}).items():
            for kind_key, tag, cell in _iter_ratio_cells(by_shape):
                target = cell.get("target", 0.7)
                if cell["ratio"] > target:
                    errors.append(f"{name}/{shape}/{kind_key}/{tag}: "
                                  f"ratio {cell['ratio']:.3f} > {target}")
                # the rs auto-selection gate: modeled bytes must beat the
                # replicated-M/V row flavour wherever rs is admissible
                if name == "sharded-row-rs" and \
                        not cell.get("below_replicated_flavor", True):
                    errors.append(
                        f"{name}/{shape}/{kind_key}/{tag}: rs bytes not "
                        "below the replicated-M/V flavour — the "
                        "auto-selection gate would never pick it")
                # the grad-fused gate: the tapped step must model
                # STRICTLY below the current fused path at every cell,
                # or emitting the tap buys nothing
                if name == "grad-fused" and not cell.get("below_fused",
                                                         False):
                    # default False: a cell MISSING the flag (stale
                    # artifact from before the gate) must fail too
                    errors.append(
                        f"{name}/{shape}/{kind_key}/{tag}: grad-fused "
                        f"ratio {cell['ratio']:.3f} not below the fused "
                        f"ratio {cell.get('fused_ratio')}")

    rows = payload.get("rows", [])
    if not rows:
        errors.append("no flat timing 'rows' recorded")
    else:
        prefixes = {r["name"].split("/", 1)[0] for r in rows
                    if isinstance(r, dict) and "/" in r.get("name", "")}
        for name in EXPECTED_SECTIONS:
            if name not in prefixes:
                errors.append(f"no timing rows with prefix {name!r}/")
    return errors


SERVE_SECTIONS = ("load", "overload", "ttft_bound")


def check_serve(path: Path) -> list[str]:
    errors: list[str] = []
    try:
        payload = json.loads(path.read_text())
    except FileNotFoundError:
        return [f"{path}: missing — run `PYTHONPATH=src python "
                "benchmarks/serve_bench.py --json`"]
    except json.JSONDecodeError as e:
        return [f"{path}: not valid JSON ({e})"]

    for name in SERVE_SECTIONS:
        if name not in payload:
            errors.append(f"serve section {name!r} missing — stale "
                          "artifact?")
    if errors:
        return errors

    for name in ("load", "overload"):
        s = payload[name]
        if s["done"] + s["shed"] + s["expired"] != s["requests"]:
            errors.append(
                f"{name}: request accounting broken — done {s['done']} + "
                f"shed {s['shed']} + expired {s['expired']} != "
                f"submitted {s['requests']}")
        if s["done"] <= 0:
            errors.append(f"{name}: nothing completed")

    load = payload["load"]
    if load.get("tok_per_s", 0) <= 0:
        errors.append("load: tok_per_s not positive")
    for pair in (("ttft_p50_s", "ttft_p99_s"),
                 ("latency_p50_s", "latency_p99_s")):
        if load.get(pair[0], 0) > load.get(pair[1], 0):
            errors.append(f"load: {pair[0]} > {pair[1]} — percentiles "
                          "out of order")
    peak = load.get("kv_occupancy_peak", -1)
    if not 0 < peak <= 1:
        errors.append(f"load: kv_occupancy_peak {peak} not in (0, 1]")
    if load.get("kv_occupancy_mean", 0) > peak:
        errors.append("load: kv_occupancy_mean above peak")
    if load.get("prefill_chunks", 0) <= load.get("done", 0):
        errors.append("load: prefill_chunks <= requests — prompts were "
                      "not chunked")

    over = payload["overload"]
    for key in ("shed", "expired", "oom_shed", "oom_deferrals"):
        if over.get(key, 0) <= 0:
            errors.append(f"overload: {key} never fired — degradation "
                          "taxonomy incomplete")

    tb = payload["ttft_bound"]
    if not tb.get("bounded", False):
        errors.append("ttft_bound: 'bounded' not true")
    if tb.get("chunked_max_gap_s", 1.0) >= tb.get("blocking_max_gap_s", 0.0):
        errors.append(
            f"ttft_bound: chunked max gap {tb.get('chunked_max_gap_s')} "
            f"not below blocking {tb.get('blocking_max_gap_s')} — "
            "chunked prefill is not bounding TTFT inflation")
    if tb.get("prefill_chunk", 0) <= 0:
        errors.append("ttft_bound: chunked run had no prefill_chunk")
    return errors


def main() -> int:
    if len(sys.argv) > 1:
        path = Path(sys.argv[1])
        targets = [(path, check_serve if "serve" in path.name.lower()
                    else check_bench)]
    else:
        targets = [(REPO / "BENCH_kernels.json", check_bench),
                   (REPO / "BENCH_serve.json", check_serve)]
    failed = False
    for path, checker in targets:
        errors = checker(path)
        for e in errors:
            print(f"[check_bench] {e}", file=sys.stderr)
        if errors:
            failed = True
        else:
            print(f"[check_bench] {path.name} OK")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
