"""Checkpoint inspector: steps, manifest schema, embedded StepPrograms.

    PYTHONPATH=src python tools/dump_ckpt.py /path/to/ckpt-dir
    PYTHONPATH=src python tools/dump_ckpt.py /path/to/ckpt-dir --step 50 \
        --leaves --verify

Prints the step directories a ``CheckpointManager`` root holds (flagging
orphaned ``.tmp`` dirs from crashed saves and marking sentinel-validated
known-good steps — the rollback targets — with ``*``), then for the
chosen step (the
newest by default): the manifest format/extras, the embedded per-leaf
StepProgram descriptors (``state_programs`` — regime, shards, state
layout, rank, method: what the elastic restore transposes from), and with
``--leaves`` the full per-leaf table.  ``--verify`` re-reads ``data.bin``
and recomputes every crc32 — the offline answer to "is this checkpoint
restorable, and if not, which leaf is damaged?".
"""

from __future__ import annotations

import argparse
import sys
import zlib
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

from repro.checkpoint.manager import CheckpointManager, load_manifest


def _fmt_bytes(n: int) -> str:
    for unit in ("B", "KiB", "MiB", "GiB"):
        if n < 1024 or unit == "GiB":
            return f"{n:.1f} {unit}" if unit != "B" else f"{n} B"
        n /= 1024
    return f"{n} B"


def _verify(path: Path, manifest: dict) -> int:
    try:
        import zstandard as zstd
        dctx = zstd.ZstdDecompressor()
    except Exception:
        dctx = None
    data = (path / "data.bin").read_bytes()
    bad = 0
    for i, meta in enumerate(manifest["leaves"]):
        blob = data[meta["offset"]:meta["offset"] + meta["nbytes"]]
        try:
            if len(blob) < meta["nbytes"]:
                raise IOError(f"truncated ({len(blob)}/{meta['nbytes']} B)")
            if meta["compressed"]:
                if dctx is None:
                    raise IOError("compressed but zstandard unavailable")
                blob = dctx.decompress(blob,
                                       max_output_size=meta["raw_nbytes"])
            if zlib.crc32(blob) != meta["crc32"]:
                raise IOError("crc32 mismatch")
        except Exception as e:
            print(f"  LEAF {i} DAMAGED: {e}")
            bad += 1
    print(f"  verify: {len(manifest['leaves']) - bad} ok, {bad} damaged")
    return bad


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("root", help="CheckpointManager root directory")
    ap.add_argument("--step", type=int, default=None,
                    help="inspect this step (default: newest)")
    ap.add_argument("--leaves", action="store_true",
                    help="print the full per-leaf manifest table")
    ap.add_argument("--verify", action="store_true",
                    help="recompute every leaf crc32 against data.bin")
    ap.add_argument("--target-mesh", action="append", type=int,
                    default=None, metavar="N",
                    help="elastic-restore admissibility report: for each "
                         "given device count (repeatable), print which "
                         "StepProgram regimes every low-rank leaf can "
                         "restore onto — the offline answer to 'can I "
                         "resume this checkpoint on N devices, and with "
                         "which sharded hot paths?'")
    args = ap.parse_args(argv)

    root = Path(args.root)
    if not root.exists():
        print(f"no such directory: {root}")
        return 1
    mgr = CheckpointManager(root)
    steps = mgr.steps()
    good = set(mgr.known_good_steps())
    tmps = sorted(p.name for p in root.iterdir()
                  if p.is_dir() and p.name.endswith(".tmp"))
    tagged = [f"{s}*" if s in good else str(s) for s in steps]
    print(f"{root}: {len(steps)} complete step(s) "
          f"[{', '.join(tagged)}]{'  (* = known-good)' if good else ''}")
    for t in tmps:
        print(f"  orphaned partial write (crashed save): {t}/")
    if not steps:
        return 0 if not args.verify else 1

    step = args.step if args.step is not None else steps[-1]
    path = root / f"step_{step:010d}"
    if not (path / "manifest.msgpack").exists():
        print(f"step {step}: no manifest at {path}")
        return 1
    manifest = load_manifest(path)
    extra = manifest.get("extra", {})
    total_raw = sum(m["raw_nbytes"] for m in manifest["leaves"])
    total_disk = sum(m["nbytes"] for m in manifest["leaves"])
    print(f"\nstep {step} ({path.name}): format {manifest['format']}, "
          f"{manifest['n_leaves']} leaves, "
          f"{_fmt_bytes(total_raw)} logical / {_fmt_bytes(total_disk)} "
          "on disk")
    print(f"  known-good: {'yes (sentinel-validated; rollback target)' if step in good else 'no'}")
    for k in ("step", "time"):
        if k in extra:
            print(f"  extra.{k}: {extra[k]}")

    programs = extra.get("state_programs")
    if programs:
        print(f"\n  state programs ({len(programs)} optimizer-state "
              "nodes):")
        for rec in programs:
            if rec["kind"] == "dense":
                print(f"    {rec['path']:40s} dense")
                continue
            print(f"    {rec['path']:40s} {rec['regime']:10s} "
                  f"g={rec['shards']} axes={tuple(rec['axes']) or '-'} "
                  f"state={rec['state_layout']:10s} "
                  f"m={rec['m']} n={rec['n']} r={rec['rank']} "
                  f"method={rec['method']}")
    else:
        print("\n  no embedded state programs (pre-elastic checkpoint: "
              "restores strict-shape only)")

    if args.target_mesh:
        if not programs:
            print("\n  --target-mesh: no embedded state programs — "
                  "elastic restore (and this report) needs them")
            return 1
        from repro.checkpoint.transpose import restore_targets
        for g in args.target_mesh:
            print(f"\n  restore onto {g} device(s) — admissible regimes "
                  "per leaf (restore itself is always admissible: layout "
                  "changes are identity; this lists the sharded hot "
                  "paths the gates admit):")
            for rec in programs:
                rep = restore_targets(rec, g)
                line = f"    {rec['path']:40s} {', '.join(rep['regimes'])}"
                if rep["notes"]:
                    line += f"   [{'; '.join(rep['notes'])}]"
                print(line)

    if args.leaves:
        print("\n  leaves:")
        for i, m in enumerate(manifest["leaves"]):
            print(f"    [{i:3d}] shape={tuple(m['shape'])!s:20s} "
                  f"{m['dtype']:10s} {_fmt_bytes(m['raw_nbytes']):>12s} "
                  f"crc32={m['crc32']:#010x}"
                  f"{' zstd' if m['compressed'] else ''}")

    if args.verify:
        print()
        return 1 if _verify(path, manifest) else 0
    return 0


if __name__ == "__main__":
    sys.exit(main())
