"""Docs hygiene checker: intra-repo links resolve and doc commands parse.

Two layers:

* link check (always): every relative markdown link in the repo's *.md
  files (root + docs/) must point at an existing file or directory;
  ``#anchors`` are stripped, external ``http(s)://`` links are skipped.
* command check (``--run``): fenced ```bash blocks in EVERY doc file
  (root + docs/ — README, architecture.md, ...) are scanned;
  ``python <script>.py`` invocations must reference existing scripts,
  and every ``python -m pytest`` invocation is executed with
  ``--collect-only -q`` appended — proving the documented verify command
  parses and the suite collects — without running the tests.

CI runs ``python tools/check_docs.py --run``; tests/test_docs.py runs the
link layer in-process so tier-1 guards the docs too.
"""

from __future__ import annotations

import re
import shlex
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
FENCE_RE = re.compile(r"```(?:bash|sh|console)\n(.*?)```", re.DOTALL)


def doc_files() -> list[Path]:
    return sorted(REPO.glob("*.md")) + sorted((REPO / "docs").glob("*.md"))


def check_links() -> list[str]:
    """Return a list of 'file: broken-link' error strings."""
    errors = []
    for md in doc_files():
        for target in LINK_RE.findall(md.read_text()):
            if target.startswith(("http://", "https://", "mailto:", "#")):
                continue
            path = target.split("#", 1)[0]
            if not path:
                continue
            resolved = (md.parent / path).resolve()
            if not resolved.exists():
                errors.append(f"{md.relative_to(REPO)}: broken link "
                              f"-> {target}")
    return errors


def doc_commands() -> list[tuple[str, str]]:
    """(doc-file, command) pairs from bash fences in every doc file.

    Continuation lines (trailing ``\\``) are joined so a wrapped pytest
    invocation is collected as one command.
    """
    pairs: list[tuple[str, str]] = []
    for md in doc_files():
        name = str(md.relative_to(REPO))
        for block in FENCE_RE.findall(md.read_text()):
            pending = ""
            for line in block.splitlines():
                line = line.strip()
                if not line or line.startswith("#"):
                    continue
                if line.endswith("\\"):
                    pending += line[:-1] + " "
                    continue
                pairs.append((name, (pending + line).strip()))
                pending = ""
            if pending:
                pairs.append((name, pending.strip()))
    return pairs


def check_commands() -> list[str]:
    """Validate doc commands: scripts exist, pytest lines collect."""
    errors = []
    for doc, cmd in doc_commands():
        parts = shlex.split(cmd)
        # skip env assignments to find the program
        prog_i = 0
        while prog_i < len(parts) and "=" in parts[prog_i]:
            prog_i += 1
        prog = parts[prog_i:] if prog_i < len(parts) else []
        if not prog or prog[0] != "python":
            continue                      # pip install etc. — not checked
        if "-m" in prog and "pytest" in prog:
            run = subprocess.run(
                cmd + " --collect-only -q", shell=True, cwd=REPO,
                capture_output=True, text=True, timeout=600)
            if run.returncode != 0:
                errors.append(
                    f"{doc} command failed to collect: {cmd!r}\n"
                    f"{run.stdout[-2000:]}{run.stderr[-2000:]}")
        elif len(prog) > 1 and prog[1].endswith(".py"):
            if not (REPO / prog[1]).exists():
                errors.append(f"{doc} references missing script: {prog[1]}")
    return errors


def main() -> int:
    errors = check_links()
    if "--run" in sys.argv:
        errors += check_commands()
    for e in errors:
        print(f"ERROR: {e}")
    if not errors:
        n_cmds = len(doc_commands()) if "--run" in sys.argv else 0
        print(f"docs OK: {len(doc_files())} files checked"
              + (f", {n_cmds} doc commands scanned" if n_cmds else ""))
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
