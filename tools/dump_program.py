"""Print the lowered StepProgram for a config / mesh / leaf shape — the
debugging story for hot-path regime selection.

For a given parameter-leaf shape, rank, PartitionSpec and mesh, this
prints what the optimizer will actually lower per step kind: the chosen
regime, the gradient/state layouts, the tracking schedule, every
collective round (name, kind, payload shape, per-device ring wire
bytes), and the modeled per-device HBM+wire bytes of the fused step vs
the paper-literal schedule distributed the same way.

Examples::

    PYTHONPATH=src python tools/dump_program.py \
        --shape 2048 4097 --rank 64 --spec model,None --mesh model=16,data=2

    PYTHONPATH=src python tools/dump_program.py \
        --shape 1024 2560 --rank 128 --spec x,None --mesh x=8 \
        --row-state replicated

    # why does this leaf NOT shard?  (indivisible n, tiny mesh, ...)
    PYTHONPATH=src python tools/dump_program.py \
        --shape 512 384 --rank 128 --spec None,x --mesh x=8

No devices are needed: programs are static data (AbstractMesh).
"""

from __future__ import annotations

import argparse

from jax.sharding import AbstractMesh, PartitionSpec as P

from repro.core import plan as plan_lib
from repro.core import program as program_lib
from repro.core.subtrack import LowRankConfig
from repro.kernels import traffic


def parse_mesh(text: str) -> AbstractMesh:
    """"model=16,data=2" -> AbstractMesh((("model", 16), ("data", 2)))."""
    pairs = []
    for part in text.split(","):
        name, _, size = part.partition("=")
        if not size:
            raise SystemExit(f"bad mesh entry {part!r}; want name=size")
        pairs.append((name.strip(), int(size)))
    return AbstractMesh(tuple(pairs))


def parse_spec(text: str | None, ndim: int) -> P:
    """"model,None" / "None,x" / "x" -> PartitionSpec (None-padded)."""
    if text is None:
        return None
    entries = []
    for part in text.split(","):
        part = part.strip()
        entries.append(None if part in ("None", "none", "-", "") else part)
    entries += [None] * (ndim - len(entries))
    return P(*entries)


def modeled_bytes(prog: program_lib.StepProgram, *,
                  grad_bytes: int, param_bytes: int,
                  recovery: bool = True) -> list[str]:
    """Fused vs paper-literal per-device byte lines for the program.

    Keyed on the program's EFFECTIVE geometry (``prog.tracks``), not the
    step kind: a tracking step whose refresh moves no basis (method
    "none") declares — and must be modeled as — the plain schedule, so
    the bytes printed here always match the rounds printed above them.
    A program carrying the ``grad_tap`` round is modeled tap-fed
    (repro.kernels.traffic.gradfused_step_bytes — no projection pass)."""
    kw = dict(grad_bytes=grad_bytes, param_bytes=param_bytes)
    m, n, r = prog.m, prog.n, prog.rank
    tracks = prog.tracks
    if prog.regime in ("replicated", "grass"):
        if not tracks and prog.round("grad_tap") is not None:
            gf = traffic.gradfused_step_bytes(m, n, r, recovery=recovery,
                                              **kw)
            unf = traffic.unfused_step_bytes(m, n, r, **kw)
            fus = traffic.fused_step_bytes(m, n, r, **kw)
            return [f"  modeled local bytes : grad-fused {gf.total:,} vs "
                    f"paper-literal {unf.total:,} "
                    f"(ratio {gf.total / unf.total:.3f}; fused-without-tap "
                    f"would be {fus.total / unf.total:.3f} — the tap "
                    "replaces the projection pass)"]
        fus = (traffic.tracking_fused_step_bytes(m, n, r, **kw) if tracks
               else traffic.fused_step_bytes(m, n, r, **kw))
        unf = (traffic.tracking_unfused_step_bytes(m, n, r, **kw)
               if tracks else traffic.unfused_step_bytes(m, n, r, **kw))
        note = ("grass — selection gather, no wire term"
                if prog.regime == "grass" else "replicated — no wire term")
        return [f"  modeled local bytes : fused {fus.total:,} vs "
                f"paper-literal {unf.total:,} "
                f"(ratio {fus.total / unf.total:.3f}; {note})"]
    fus_fn, unf_fn = traffic._REGIME_MODEL_FNS[(prog.regime, tracks)]
    fus = fus_fn(m, n, r, prog.shards, **kw)
    unf = unf_fn(m, n, r, prog.shards, **kw)
    return [
        f"  modeled bytes/device: fused {fus.total:,} "
        f"(local {fus.local.total:,} + wire {fus.collective_bytes:,}) vs "
        f"paper-literal {unf.total:,}",
        f"  fused/literal ratio : {fus.total / unf.total:.3f}",
    ]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--shape", type=int, nargs="+", required=True,
                    help="parameter leaf shape, e.g. --shape 2048 4097 "
                         "or --shape 3 1024 2560 (leading stack dims ok)")
    ap.add_argument("--rank", type=int, required=True)
    ap.add_argument("--spec", default=None,
                    help="comma-separated PartitionSpec entries in the "
                         "LEAF's layout, e.g. 'model,None' or 'None,x' "
                         "(default: unsharded)")
    ap.add_argument("--mesh", default=None,
                    help="mesh axes as name=size pairs, e.g. "
                         "'model=16,data=2' (default: no mesh — the "
                         "replicated program)")
    ap.add_argument("--method", default="grassmann")
    ap.add_argument("--row-state", default="auto",
                    choices=["auto", "replicated", "reduce-scatter"])
    ap.add_argument("--reorth-interval", type=int, default=0)
    ap.add_argument("--no-recovery", action="store_true")
    ap.add_argument("--grad-fused", action="store_true",
                    help="build the tapped program: plain steps carry the "
                         "grad_tap round (backward-pass [A; colnorms] "
                         "panel) where the regime admits it")
    ap.add_argument("--grad-bytes", type=int, default=4,
                    help="gradient dtype width (2 for bf16)")
    ap.add_argument("--param-bytes", type=int, default=4)
    args = ap.parse_args(argv)

    shape = tuple(args.shape)
    mesh = parse_mesh(args.mesh) if args.mesh else None
    spec = parse_spec(args.spec, len(shape))
    plan = plan_lib.plan_for_shape(shape, args.rank, spec=spec)
    cfg = LowRankConfig(rank=args.rank, method=args.method,
                        use_kernels=True, row_state=args.row_state,
                        reorth_interval=args.reorth_interval,
                        recovery=not args.no_recovery)

    print(f"leaf shape {shape}  spec {spec}  rank {args.rank}  "
          f"mesh {args.mesh or '-'}")
    if plan.mode != "lowrank":
        print("plan: DENSE (min trailing dim <= rank) — plain Adam, "
              "no program")
        return 0
    print(f"canonical (m, n) = ({plan.m}, {plan.n})"
          + ("  [transposed]" if plan.transpose else "")
          + (f"  stack dims = {plan.batch_dims}" if plan.batch_dims
             else ""))
    for tracking, title in ((False, "plain step (k-1 of k)"),
                            (True, "tracking step (1 of k)")):
        prog = program_lib.build_program(plan, cfg, mesh,
                                         tracking=tracking,
                                         tapped=args.grad_fused)
        print(f"\n== {title} ==")
        print(prog.describe())
        for line in modeled_bytes(prog, grad_bytes=args.grad_bytes,
                                  param_bytes=args.param_bytes,
                                  recovery=not args.no_recovery):
            print(line)
        if prog.regime == "replicated" and mesh is not None:
            print("  (replicated: leaf/config not admissible for any "
                  "shard_map regime — check spec orientation, the "
                  "n/g >= 2r / m/g >= 2r gates, lead-dim sharding, or a "
                  "non-shardable refresh method on tracking steps)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
